"""Dry-run artifact analysis: cost/memory extraction + HLO collective parsing
+ the three-term roofline.

cost_analysis() has no collective accounting, so collective bytes are parsed
from the optimized HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute contributes its result-buffer bytes, scaled
by a ring-transfer factor to per-device wire bytes.  Collectives on small
integer/fp32 tensors (dispatch plans, counts) are ALSO tallied separately as
*control-plane bytes* — the framework analogue of the paper's Table 6 claim
that a dedicated control network costs 11.5% of fabric area.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, List, Optional, Tuple

# -- TPU v5e-class hardware constants (per chip) ------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# control-plane heuristic: integer payloads, or tiny (<=256 KiB) fp payloads
CONTROL_BYTES_LIMIT = 256 * 1024

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        members = [t for t in first.replace("{", "").split(",") if t.strip() != ""]
        if members:
            return len(members)
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Any]:
    """Per-device collective byte accounting from optimized HLO."""
    per_op: Dict[str, Dict[str, float]] = {
        op: {"count": 0, "result_bytes": 0, "wire_bytes": 0} for op in _COLLECTIVES
    }
    control_bytes = 0.0
    total_wire = 0.0

    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        rhs = rhs.strip()
        op = next(
            (
                c for c in _COLLECTIVES
                if rhs.split("(")[0].strip().split(" ")[-1].startswith(c)
                and not rhs.split("(")[0].strip().split(" ")[-1].startswith(c + "-done")
            ),
            None,
        )
        if op is None:
            continue
        head = rhs.split("(")[0]
        if f"{op}-done" in head:
            continue  # bytes counted at -start
        # result shapes live between '=' and the op name
        result_part = head
        shapes = _SHAPE_RE.findall(result_part)
        rbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if rbytes == 0:
            continue
        g = _group_size(stripped, n_devices)
        ring = (g - 1) / g if g > 1 else 0.0
        if op == "all-reduce":
            wire = 2.0 * rbytes * ring        # reduce-scatter + all-gather phases
        elif op == "collective-permute":
            wire = float(rbytes)
        else:
            wire = rbytes * ring
        per_op[op]["count"] += 1
        per_op[op]["result_bytes"] += rbytes
        per_op[op]["wire_bytes"] += wire
        total_wire += wire
        ints_only = all(dt.startswith(("s", "u", "pred")) for dt, _ in shapes)
        if ints_only or rbytes <= CONTROL_BYTES_LIMIT:
            control_bytes += wire

    return {
        "per_op": per_op,
        "wire_bytes": total_wire,
        "control_wire_bytes": control_bytes,
        "control_share": control_bytes / total_wire if total_wire else 0.0,
    }


def extract_cost(compiled) -> Dict[str, float]:
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {k: float(v) for k, v in dict(cost).items() if isinstance(v, (int, float))}


def extract_memory(compiled) -> Dict[str, float]:
    out: Dict[str, float] = {}
    try:
        mem = compiled.memory_analysis()
    except Exception:
        return out
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = float(v)
    if not out and mem is not None:
        out["repr"] = 0.0
    return out


def analytic_memory_bytes(cfg, cell, n_model: int, n_data: int) -> Dict[str, float]:
    """Per-device HBM traffic model for the TPU target (bytes / step).

    cost_analysis' "bytes accessed" on the CPU backend counts every operand of
    every unfused op (~100x the HBM traffic a fused TPU program sees), so the
    memory roofline term uses this explicit model instead; the HLO number is
    still reported alongside.  Assumptions (documented in EXPERIMENTS.md):

    * weights: f32, sharded over `model`, replicated over `data`.
      train: 3 reads (fwd, remat-recompute, bwd) + grad write/read + optimizer
      read-modify-write  -> ~10x param bytes (adamw) / ~6x (adafactor).
      prefill/decode: 1 read of every (active) weight.
    * activations: residual stream replicated over `model`; projection
      intermediates sharded.  Per layer ~6 residual-sized tensors + ~4
      sharded FFN-width tensors materialize; x4 for train (fwd + recompute +
      bwd read&write), x1 otherwise.
    * decode reads the full KV cache (or recurrent state) per token — the
      canonical decode memory wall.
    * MoE: only top-k expert weights are touched per token on average, but
      whole expert shards stream when every expert receives tokens; we charge
      min(local expert bytes, token-driven traffic).
    """
    d = cfg.d_model
    pf = 4  # param bytes (f32 master)
    ab = 2 if cell.step != "train" or cfg.dtype == "bfloat16" else 2  # bf16 acts
    B, S = cell.global_batch, cell.seq_len
    # tokens per device: batch over data, sequence kept whole
    B_loc = max(B // n_data, 1)
    T_loc = B_loc * (S if cell.step in ("train", "prefill") else 1)

    counts = cfg.param_counts()
    total_param_b = cfg.num_params() * pf
    active_param_b = cfg.num_active_params() * pf
    pb_local = total_param_b / n_model
    pb_active_local = active_param_b / n_model

    if cell.step == "train":
        opt_mult = 10.0 if cfg.optimizer == "adamw" else 6.0
        weight_traffic = opt_mult * pb_local
        act_mult = 4.0
    else:
        weight_traffic = pb_active_local
        act_mult = 1.0

    A_res = T_loc * d * ab
    traffic = 0.0
    for kind in cfg.layer_kinds:
        if kind in ("attn", "local", "moe"):
            dff = (cfg.d_ff_expert or cfg.d_ff) if kind == "moe" else cfg.d_ff
            width = dff * (cfg.top_k if kind == "moe" else 1)
            layer = 6 * A_res + 4 * T_loc * (width / n_model if kind != "moe" else width) * ab
            ctx = min(S, cfg.local_window or S)
            if cell.step == "decode":
                # full KV cache read per token
                layer += B_loc * ctx * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * ab
            else:
                layer += T_loc * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * ab
        elif kind == "rec":
            layer = 6 * A_res + 6 * T_loc * (cfg.lru_width / n_model) * ab * 2  # f32 scan
        elif kind == "ssm":
            d_in = cfg.ssm_expand * d
            layer = 4 * A_res + 8 * T_loc * (d_in / n_model) * ab
            if cell.step == "decode":
                layer += B_loc * (d_in // cfg.ssm_head_dim) * cfg.ssm_state * cfg.ssm_head_dim / n_model * 4 * 2
        else:
            layer = 6 * A_res
        traffic += layer * act_mult

    # embeddings + logits (vocab sharded over model when divisible)
    v_shard = cfg.vocab_size / (n_model if cfg.vocab_size % n_model == 0 else 1)
    traffic += T_loc * d * ab + act_mult * T_loc * v_shard * 4

    return {
        "weight_bytes": weight_traffic,
        "activation_bytes": traffic,
        "total_bytes": weight_traffic + traffic,
    }


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS: 6*N_active*D for training (fwd+bwd), 2*N_active*D for a
    forward-only step (prefill processes D=B*S tokens; decode D=B tokens)."""
    n = cfg.num_active_params()
    if cell.step == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.step == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


def roofline(
    cost: Dict[str, float],
    coll: Dict[str, Any],
    cfg,
    cell,
    n_devices: int,
    mesh_shape: Optional[Dict[str, int]] = None,
) -> Dict[str, Any]:
    """Three roofline terms in seconds (per-device, per-step).

    cost_analysis() of the SPMD-partitioned executable reports PER-DEVICE
    flops/bytes (the compiled module is the per-device program), so terms
    divide by single-chip peaks.  memory_s uses the analytic HBM-traffic
    model (see :func:`analytic_memory_bytes`); the raw CPU-backend HLO bytes
    are reported as ``memory_s_hlo`` with their fusion caveat.
    """
    n_model = (mesh_shape or {}).get("model", 16)
    n_data = 1
    for a in ("pod", "data"):
        n_data *= (mesh_shape or {"data": n_devices // n_model}).get(a, 1)

    flops = cost.get("flops", 0.0)
    bytes_hlo = cost.get("bytes accessed", 0.0)
    mem_model = analytic_memory_bytes(cfg, cell, n_model, n_data)
    compute_s = flops / PEAK_FLOPS
    memory_s = mem_model["total_bytes"] / HBM_BW
    collective_s = coll["wire_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)
    mf_per_dev = mf / n_devices
    step_s = max(terms.values())
    useful = mf_per_dev / flops if flops else 0.0
    # achievable fraction of compute roofline given the dominant term
    roofline_frac = (mf_per_dev / PEAK_FLOPS) / step_s if step_s else 0.0
    return {
        **terms,
        "memory_s_hlo": bytes_hlo / HBM_BW,
        "memory_bytes_model": mem_model,
        "bottleneck": bottleneck,
        "model_flops_total": mf,
        "model_flops_per_device": mf_per_dev,
        "hlo_flops_per_device": flops,
        "useful_flop_ratio": useful,
        "roofline_fraction": roofline_frac,
        "control_share_of_wire": coll.get("control_share", 0.0),
    }
