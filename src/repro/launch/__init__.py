"""Launchers: production mesh construction, step builders (train / prefill /
serve), the multi-pod dry-run (lower + compile + roofline terms for every
arch x shape x mesh), and the real train/serve drivers.

NOTE: importing this package must NOT touch jax device state — meshes are
built by functions only (dryrun.py sets XLA_FLAGS before any jax import).
"""
