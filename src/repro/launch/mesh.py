"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never initializes jax's device backend — required because the dry-run forces
512 host devices while tests/benchmarks must see 1.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The target deployment mesh.

    single pod:  (data=16, model=16)          = 256 chips (TPU v5e pod)
    multi-pod:   (pod=2, data=16, model=16)   = 512 chips

    ``pod`` composes with ``data`` for batch/gradient parallelism; its
    reduction hop crosses the (slow) inter-pod links, which is why the
    trainer's hierarchical reduction treats it separately.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh over available devices (tests: small host meshes)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small (data, model) mesh over however many host devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))


def elastic_mesh(n_healthy: int, *, model: int = 16, multi_pod: bool = False) -> Mesh:
    """Elastic re-shape: rebuild the largest (data, model) mesh that fits the
    surviving device count, keeping the model axis fixed (parameter sharding
    must stay valid) and shrinking the data axis.  Used by runtime.elastic on
    (injected) node failures."""
    if n_healthy < model:
        raise ValueError(f"cannot keep model={model} with {n_healthy} devices")
    data = n_healthy // model
    devices = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devices, ("data", "model"))
