"""Host-side speculative-decode bookkeeping shared by the serve drivers.

The verify rule is the greedy one: position 0 of a launch is the model's own
next token (always accepted); draft position t stays accepted while the
draft token equals what the model emitted for position t-1.  Everything the
rollback guarantee rests on (overwritten KV rows, plan-row selection by
accepted count) keys off the count returned here, so the drivers and the
example share ONE implementation.

Tree drafts generalize the chain: :func:`greedy_accept_tree` walks a
:class:`~repro.core.plans.TreePlan` from the root, descending into the child
whose draft token matches the model's emission for the current node, and
returns the accepted root path as NODE INDICES.  By construction the path is
connected and starts at the root — a token on a rejected branch can never be
committed.  For a chain tree the walk degenerates to :func:`greedy_accept`
(node index == position).

Drafting policies live here too:

* :func:`draft_tree_repeat` / :func:`draft_tree_ngram` — host-side
  heuristics filling a tree shape (ngram fills sibling slots with DISTINCT
  historical successors, most recent first — the tree's whole point is to
  hedge across alternatives);
* :class:`ModelDrafter` — a small draft model batched through the same
  decode plane as the target (per-depth batched ``decode_tokens`` launches
  over the slot pool), emitting top-k branching drafts.

Request programs (``core.programs``) hook in at two points, and the two
must stay consistent:

* :func:`accept_tree_program` is the program-aware verify walk — emissions
  advance the automaton and the walk stops the moment it enters an
  accepting state (earliest-accept), so no token past the grammar's end is
  ever committed;
* :func:`steer_tree_tokens` (host drafters) and ``ModelDrafter.propose``'s
  ``guides`` (draft-model logit masking) clamp every drafted token to the
  automaton's allowed set at its node's state.  Steering never changes
  which tokens get committed — the masked verify does that — it only stops
  drafters proposing tokens the verifier is guaranteed to reject, which is
  why constrained streams speed speculation up instead of fighting it.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plans import TreePlan


def greedy_accept(draft_row, verified_row, width: int, budget: int) -> int:
    """Accepted-token count for one sequence's launch.

    draft_row     (T,) the launched tokens (index 0 = last accepted token)
    verified_row  (T,) argmax of the launch logits (successor per position)
    width         T, the speculative width
    budget        remaining tokens this sequence may still emit (>= 1)
    """
    a = 1
    while a < width and a < budget and int(draft_row[a]) == int(verified_row[a - 1]):
        a += 1
    return a


def greedy_accept_tree(draft_row, verified_row, tree: TreePlan, budget: int) -> List[int]:
    """Greedy tree verification: the accepted root path, as node indices.

    Walk from the root: the model's emission for the current node
    (``verified_row[cur]``) is the sequentially-correct next token; descend
    into the first child drafted with exactly that token, stop when no child
    matches (or the budget is exhausted).  Every returned node is on one
    root-to-leaf path — a sibling of an accepted node is never committed, so
    the emitted tokens ``verified_row[path]`` are exactly what sequential
    greedy decode produces.  A chain tree reproduces :func:`greedy_accept`:
    ``len(path) == greedy_accept(...)``.
    """
    kids = tree.children()
    path = [0]
    cur = 0
    while len(path) < budget:
        want = int(verified_row[cur])
        nxt = next((c for c in kids[cur] if int(draft_row[c]) == want), None)
        if nxt is None:
            break
        path.append(nxt)
        cur = nxt
    return path


def accept_tree_program(draft_row, verified_row, tree: TreePlan, budget: int,
                        automaton, state0: int) -> Tuple[List[int], int, bool]:
    """Program-aware greedy tree verification.

    Same walk as :func:`greedy_accept_tree` — the verified emissions along
    the accepted path equal draft tokens, so advancing the automaton by each
    emission tracks exactly the committed stream's state — plus the
    earliest-accept stop: the walk ends the moment an emission drives the
    automaton into an accepting state, so nothing past the grammar's end is
    committed even when deeper draft nodes happen to match.

    Returns ``(path, state_after, done)``: the accepted node path, the
    automaton state after the path's emissions (this becomes the slot's
    carried state — rollback-exact, because rejected nodes never advanced
    it), and whether the stream completed.
    """
    kids = tree.children()
    path = [0]
    cur = 0
    st = int(state0)
    while True:
        want = int(verified_row[cur])
        st = automaton.step(st, want)
        if st < 0 or automaton.is_accept(st) or len(path) >= budget:
            break
        nxt = next((c for c in kids[cur] if int(draft_row[c]) == want), None)
        if nxt is None:
            break
        path.append(nxt)
        cur = nxt
    return path, st, automaton.is_accept(st)


# ---------------------------------------------------------------------------
# tree drafters (host-side heuristics)
# ---------------------------------------------------------------------------


def _followers(history: Sequence[int], tok: int, limit: int) -> List[int]:
    """Distinct tokens that followed ``tok`` in history, most recent first."""
    out: List[int] = []
    for i in range(len(history) - 2, -1, -1):
        if history[i] == tok and history[i + 1] not in out:
            out.append(history[i + 1])
            if len(out) >= limit:
                break
    return out


def draft_tree_repeat(history, last_tok: int, tree: TreePlan) -> List[int]:
    """Every node repeats the last accepted token (minimal drafter: siblings
    are duplicates, so this exercises verify's first-match tie-break and the
    worst-case rejection path)."""
    return [int(last_tok)] * tree.num_nodes


def draft_tree_ngram(history, last_tok: int, tree: TreePlan) -> List[int]:
    """Bigram-lookup tree drafter: each node's children are the DISTINCT
    tokens that followed the node's token in history (most recent first, one
    per sibling slot; slots beyond the evidence repeat the parent token)."""
    toks = [0] * tree.num_nodes
    toks[0] = int(last_tok)
    kids = tree.children()
    for node, children in enumerate(kids):
        if not children:
            continue
        cand = _followers(history, toks[node], len(children))
        for rank, child in enumerate(children):
            toks[child] = cand[rank] if rank < len(cand) else toks[node]
    return toks


TREE_DRAFTERS = {"repeat": draft_tree_repeat, "ngram": draft_tree_ngram}


def steer_tree_tokens(toks_row, tree: TreePlan, automaton, state0: int,
                      history: Sequence[int] = ()) -> np.ndarray:
    """Clamp a filled draft tree to the automaton's allowed sets.

    Walks the tree in topological order carrying the automaton state per
    node (node 0 is the already-committed last token, so its state is the
    slot state itself).  A drafted token outside its node's allowed set is
    replaced — preferring historical followers that ARE allowed, then the
    lowest allowed ids — and duplicate siblings are diversified across the
    allowed set (a duplicate sibling can never out-accept its twin, so the
    slot is free hedging).  Past an accepting or rejected state the draft is
    dead weight either way and passes through untouched.
    """
    toks = [int(v) for v in toks_row]
    kids = tree.children()
    states = [-1] * tree.num_nodes
    states[0] = int(state0)
    for node, children in enumerate(kids):
        if not children:
            continue
        ps = states[node]
        if ps < 0 or automaton.is_accept(ps):
            for c in children:
                states[c] = ps  # stream already ended (or died): don't-care
            continue
        allow = automaton.allowed(ps)
        allow_set = {int(v) for v in allow}
        cand = [f for f in _followers(history, toks[node], len(children) + 4)
                if f in allow_set]
        used: set = set()
        for c in children:
            if toks[c] not in allow_set or toks[c] in used:
                pick = next((f for f in cand if f not in used), None)
                if pick is None:
                    pick = next((int(v) for v in allow if int(v) not in used),
                                int(allow[0]))
                toks[c] = pick
            used.add(toks[c])
            states[c] = automaton.step(ps, toks[c])
    return np.asarray(toks, np.int32)


# ---------------------------------------------------------------------------
# model-based drafter
# ---------------------------------------------------------------------------


class ModelDrafter:
    """A small draft model proposing top-k branching drafts, batched through
    the SAME decode plane the target model serves on.

    The drafter owns a slot-pool cache shaped like the target's
    (``init_cache(slots, max_len)``), admits prompts by B=1 prefill +
    ``write_cache_slot`` (mirroring target admission), and keeps itself
    synchronized with the *accepted* token stream by replaying missed tokens
    through batched width-1 ``decode_tokens`` launches (the same ragged
    length-vector control word).  :meth:`propose` then runs one batched
    draft-model launch per tree depth: the spine follows the draft model's
    argmax, sibling slots take the next-ranked logits (top-k branching).

    Draft rows written during ``propose`` are scratch: positions at or past a
    slot's committed length are re-fed (or overwritten) before they are ever
    attended, because the length-clamp contract means no launch reads past
    its own row vector.
    """

    def __init__(self, model, params, slots: int, max_len: int):
        import jax
        import numpy as np

        self._jax, self._np = jax, np
        self.model, self.params = model, params
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.fed = np.zeros((slots,), np.int32)  # cache rows holding real tokens
        self.pending: List[List[int]] = [[] for _ in range(slots)]
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(lambda p, c, t, l: model.decode_tokens(p, c, t, l))
        self._admit = jax.jit(model.write_cache_slot)

    def admit(self, slot: int, prompt) -> None:
        """Prefill the admitted prompt into the drafter's slot cache."""
        _, one = self._prefill(
            self.params, prompt[None], self.model.init_cache(1, self.max_len)
        )
        self.cache = self._admit(self.cache, one, slot)
        self.fed[slot] = len(prompt)
        self.pending[slot] = []

    def observe(self, slot: int, tokens: Sequence[int]) -> None:
        """Queue accepted tokens (rows ``fed..`` of the true stream) for
        replay; called by the serve loop after each verify."""
        self.pending[slot].extend(int(t) for t in tokens)

    def _advance(self, toks, lens):
        jnp = self._jax.numpy
        logits, self.cache = self._step(
            self.params, self.cache,
            jnp.asarray(toks)[:, None], jnp.asarray(lens),
        )
        return self._np.asarray(logits[:, 0])

    def catch_up(self) -> None:
        """Replay queued accepted tokens (batched across slots).

        Slots with nothing pending park their step at the scratch row
        ``fed[b]`` — one past their valid prefix — which the next real feed
        or propose step overwrites before anything attends to it (the
        length-clamp contract: no launch reads past its own row vector).
        """
        np = self._np
        B = len(self.pending)
        while any(self.pending):
            toks = np.zeros((B,), np.int32)
            lens = self.fed.copy()
            adv = np.zeros((B,), np.int32)
            for b in range(B):
                if self.pending[b]:
                    toks[b] = self.pending[b].pop(0)
                    adv[b] = 1
            self._advance(toks, lens)
            self.fed = self.fed + adv

    def propose(self, last_tok, lengths, tree: TreePlan, guides=None):
        """(B,) last accepted tokens + committed lengths -> (B, T) tree tokens.

        One batched draft launch per tree depth; children of the spine node
        at depth d get the draft model's top-``len(children)`` tokens, the
        first child (the spine) continues from the top-1.

        ``guides`` (optional, per slot) is ``(automaton, state)`` for
        program-constrained slots or None: the draft model's logits are
        masked to the automaton's allowed set at the slot's spine state
        before ranking, so branching spends its sibling budget on tokens the
        masked verifier could actually accept.  Sibling ranks past the
        allowed-set size fall back to the top allowed token (a duplicate
        hedge beats a guaranteed rejection).
        """
        np = self._np
        B = len(last_tok)
        T = tree.num_nodes
        kids = tree.children()
        spine = tree.spine()
        toks = np.zeros((B, T), np.int32)
        toks[:, 0] = last_tok
        cur = np.asarray(last_tok, np.int32).copy()
        pos = np.asarray(lengths, np.int32).copy()
        states = [None if guides is None or guides[b] is None
                  else int(guides[b][1]) for b in range(B)]
        for d, node in enumerate(spine):
            children = kids[node]
            if not children:
                break
            logits = self._advance(cur, pos)
            top = np.argsort(-logits, axis=-1)[:, : len(children)].copy()
            for b in range(B):
                if states[b] is None:
                    continue
                auto, st = guides[b][0], states[b]
                if st < 0 or auto.is_accept(st):
                    continue  # stream over (or dead): draft is don't-care
                allow = auto.allowed(st)
                neg = np.finfo(np.float32).min
                masked = np.where(auto.mask(st), logits[b].astype(np.float32), neg)
                order = np.argsort(-masked)
                for rank in range(len(children)):
                    top[b, rank] = order[rank] if rank < len(allow) else order[0]
            for rank, child in enumerate(children):
                toks[:, child] = top[:, rank]
            for b in range(B):
                if states[b] is not None and states[b] >= 0:
                    states[b] = guides[b][0].step(states[b], int(top[b, 0]))
            cur = top[:, 0].astype(np.int32)
            pos += 1
        return toks
