"""Host-side speculative-decode bookkeeping shared by the serve drivers.

The verify rule is the greedy one: position 0 of a launch is the model's own
next token (always accepted); draft position t stays accepted while the
draft token equals what the model emitted for position t-1.  Everything the
rollback guarantee rests on (overwritten KV rows, plan-row selection by
accepted count) keys off the count returned here, so the drivers and the
example share ONE implementation.
"""
from __future__ import annotations


def greedy_accept(draft_row, verified_row, width: int, budget: int) -> int:
    """Accepted-token count for one sequence's launch.

    draft_row     (T,) the launched tokens (index 0 = last accepted token)
    verified_row  (T,) argmax of the launch logits (successor per position)
    width         T, the speculative width
    budget        remaining tokens this sequence may still emit (>= 1)
    """
    a = 1
    while a < width and a < budget and int(draft_row[a]) == int(verified_row[a - 1]):
        a += 1
    return a
