import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory / cost / collective analysis.

MUST be run as its own process (the two lines above force 512 host devices
before jax initializes — never set that globally).

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --cell train_4k --out results/dryrun
    python -m repro.launch.dryrun --arch qwen3-32b --cell train_4k --multi-pod ...
    python -m repro.launch.dryrun --all --out results/dryrun            # sequential
    python -m repro.launch.dryrun --list                                 # print cells
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path


def _twin_extrapolate(cfg, cell, mesh, n_dev, strategy="tp"):
    """Exact per-layer costs via two small compiled twins.

    HloCostAnalysis counts while-loop (lax.scan) bodies ONCE regardless of
    trip count, so the full model's cost_analysis underreports by ~num_layers.
    The twins unroll every inner scan (KV blocks, SSD chunks) and use scan
    length 1 over one / two superblocks, making their compiled counts exact;
    the full-depth cost is then c1 + (L/P - 1) * (c2 - c1).
    """
    import dataclasses

    from repro.launch.analysis import extract_cost, parse_collectives
    from repro.launch.steps import build_step

    P = len(cfg.block_pattern)
    twin1 = dataclasses.replace(cfg, num_layers=P, analysis_unroll=True)
    twin2 = dataclasses.replace(
        cfg, block_pattern=cfg.block_pattern * 2, num_layers=2 * P, analysis_unroll=True
    )
    out = []
    for tw in (twin1, twin2):
        with mesh:
            compiled = build_step(tw, mesh, cell, strategy=strategy).lower().compile()
        cost = extract_cost(compiled)
        coll = parse_collectives(compiled.as_text(), n_dev)
        out.append(
            {
                "flops": cost.get("flops", 0.0),
                "bytes": cost.get("bytes accessed", 0.0),
                "transcendentals": cost.get("transcendentals", 0.0),
                "wire_bytes": coll["wire_bytes"],
                "control_wire_bytes": coll["control_wire_bytes"],
            }
        )
    n_eff = cfg.num_layers / P
    est = {
        # clamp: per-layer deltas can be sub-noise at decode scale
        k: max(out[0][k] + (n_eff - 1.0) * (out[1][k] - out[0][k]), 0.0)
        for k in out[0]
    }
    est["twin1"] = out[0]
    est["twin2"] = out[1]
    est["superblocks_effective"] = n_eff
    return est


def run_cell(arch: str, cell_name: str, multi_pod: bool, out_dir: Path, strategy: str = "tp",
             capacity_factor: float = None) -> dict:
    import jax

    from repro.configs import SHAPE_CELLS, cells_for, get_config
    from repro.launch.analysis import extract_cost, extract_memory, parse_collectives, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    cfg = get_config(arch)
    if capacity_factor is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    cell = SHAPE_CELLS[cell_name]
    if cell not in cells_for(cfg):
        return {
            "arch": arch, "cell": cell_name, "multi_pod": multi_pod,
            "status": "skipped",
            "reason": "long_500k needs sub-quadratic sequence mixing (full attention arch)",
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "cell": cell_name, "multi_pod": multi_pod, "strategy": strategy,
        "mesh": dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))),
        "step": cell.step, "status": "error",
    }
    t0 = time.time()
    try:
        with mesh:
            bundle = build_step(cfg, mesh, cell, strategy=strategy)
            lowered = bundle.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = extract_cost(compiled)
        mem = extract_memory(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = parse_collectives(hlo, n_dev)

        # twin extrapolation for exact per-step counts (single-pod roofline
        # table only; the multi-pod pass proves the pod axis shards)
        est = None
        if not multi_pod:
            try:
                est = _twin_extrapolate(cfg, cell, mesh, n_dev, strategy=strategy)
                cost_x = dict(cost, **{"flops": est["flops"], "bytes accessed": est["bytes"]})
                coll_x = dict(
                    coll,
                    wire_bytes=est["wire_bytes"],
                    control_wire_bytes=est["control_wire_bytes"],
                    control_share=(
                        est["control_wire_bytes"] / est["wire_bytes"]
                        if est["wire_bytes"]
                        else 0.0
                    ),
                )
            except Exception as te:
                est = {"error": f"{type(te).__name__}: {te}"}
                cost_x, coll_x = cost, coll
        else:
            cost_x, coll_x = cost, coll
        roof = roofline(cost_x, coll_x, cfg, cell, n_dev, mesh_shape=rec["mesh"])
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=n_dev,
            cost=cost,
            memory=mem,
            collectives={
                "wire_bytes": coll["wire_bytes"],
                "control_wire_bytes": coll["control_wire_bytes"],
                "control_share": coll["control_share"],
                "per_op": {
                    k: v for k, v in coll["per_op"].items() if v["count"]
                },
            },
            roofline=roof,
            twin_extrapolation=est,
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # a dry-run failure is a bug in the system
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{cell_name}__{'pod2' if multi_pod else 'pod1'}"
    if strategy != "tp":
        tag += f"__{strategy}"
    if capacity_factor is not None:
        tag += f"__cf{capacity_factor}"
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=2, default=float))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="architecture id (see --list)")
    ap.add_argument("--cell", help="shape cell (train_4k|prefill_32k|decode_32k|long_500k)")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh (512 chips)")
    ap.add_argument("--all", action="store_true", help="run every (arch, cell) sequentially")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--strategy", default="tp", choices=("tp", "fsdp"))
    ap.add_argument("--cf", type=float, default=None, help="MoE capacity_factor override")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs import cells_for, get_config, list_archs

    if args.list:
        for a in list_archs():
            cfg = get_config(a)
            print(a, "->", ",".join(c.name for c in cells_for(cfg)))
        return 0

    out = Path(args.out)
    if args.all:
        ok = True
        for a in list_archs():
            for c in cells_for(get_config(a)):
                for mp in (False, True):
                    rec = run_cell(a, c.name, mp, out)
                    print(
                        f"{a:26s} {c.name:12s} {'pod2' if mp else 'pod1':5s} "
                        f"{rec['status']:8s} {rec.get('error', '')}"
                    )
                    ok &= rec["status"] in ("ok", "skipped")
        return 0 if ok else 1

    rec = run_cell(args.arch, args.cell, args.multi_pod, out, strategy=args.strategy,
                   capacity_factor=args.cf)
    print(json.dumps({k: v for k, v in rec.items() if k not in ("traceback",)}, indent=2, default=float))
    if rec["status"] == "error":
        print(rec.get("traceback", ""), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
