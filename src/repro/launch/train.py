"""Production training driver: the same step the dry-run compiles, wrapped in
the fault-tolerant runtime.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --smoke --steps 50 --data 1 --model 1

On a real pod, omit --smoke and pass --data/--model matching the slice; the
trainer handles checkpoint/restart and straggler observation.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="checkpoints/train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import ShapeCell
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import Trainer, TrainerConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cell = ShapeCell("cli", seq_len=args.seq_len, global_batch=args.batch, step="train")
    mesh = make_host_mesh(args.data, args.model)

    def log(step, m):
        print(f"step {step:6d}  loss {m['loss']:.4f}  {m['step_time_s']*1e3:.0f} ms")

    tr = Trainer(
        cfg, cell, mesh,
        TrainerConfig(
            num_steps=args.steps, checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.ckpt, lr=args.lr, log_every=10,
        ),
        on_metrics=log,
    )
    out = tr.run()
    print(f"finished: step {out['final_step']}, loss {out['final_loss']:.4f}, "
          f"restarts {out['restarts']}")


if __name__ == "__main__":
    main()
