"""Step builders: assemble the jitted train / prefill / serve steps with full
sharding specifications for a (config, mesh, shape-cell) triple.

These are shared by the dry-run (lower/compile against ShapeDtypeStructs) and
the real drivers (train.py / serve.py) — the dry-run compiles EXACTLY what
the drivers run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import transformer as T
from repro.models.model import Model
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer
from repro.parallel.moe_parallel import make_sharded_decode_apply, make_sharded_moe_apply
from repro.parallel.sharding import (
    batch_spec,
    cache_shardings,
    data_axes,
    param_pspecs,
    param_shardings,
)

Params = Dict[str, Any]


@dataclasses.dataclass
class StepBundle:
    """A fully-specified step: fn + in/out shardings + abstract inputs."""

    name: str
    fn: Callable
    in_shardings: Tuple
    out_shardings: Any
    abstract_inputs: Tuple
    donate_argnums: Tuple[int, ...]
    model: Model

    def jit(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jit().lower(*self.abstract_inputs)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )


def build_model(cfg: ModelConfig, mesh: Mesh, batch: int, *, strategy: str = "tp") -> Model:
    """Model with the distributed MoE apply + residual constraint bound to
    this mesh/batch."""
    baxes = batch_spec(batch, mesh)[0] or ()
    baxes = (baxes,) if isinstance(baxes, str) else tuple(baxes)
    moe_apply = None
    decode_apply = None
    if cfg.is_moe:
        raw = make_sharded_moe_apply(cfg, mesh, baxes)

        def moe_apply(x, rs, p):
            y, aux = raw(x, rs, p)
            return y, aux

        if cfg.decode_plane:
            # distributed decode plane: cache-carried DecodePlans execute as
            # per-shard slices + one psum instead of the replicated fallback
            # (raises, not falls back, when experts don't divide the mesh)
            decode_apply = make_sharded_decode_apply(cfg, mesh, baxes)

    res_spec = P(baxes or None, None, None)
    if strategy == "fsdp":
        axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if batch % total == 0:
            res_spec = P(axes, None, None)

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, res_spec))

    return Model(cfg, moe_apply=moe_apply, constrain=constrain, decode_moe_apply=decode_apply)


def opt_state_pspecs(opt_state_abs: Any, params_abs: Any, mesh: Mesh, *, strategy: str = "tp") -> Any:
    """Shardings for optimizer state, derived from the param shardings.

    adamw: state mirrors params ({"m": tree, "v": tree}).
    adafactor: dict leaves {v} | {vr, vc} with reduced shapes — keep the
    model-sharded axis when it survives the factoring, else replicate.
    """
    pspecs = param_pspecs(params_abs, mesh, strategy=strategy)

    if isinstance(opt_state_abs, dict) and set(opt_state_abs) <= {"m", "v", "count"}:
        return {k: jax.tree.map(lambda s: s, pspecs) for k in opt_state_abs}

    # adafactor: params tree with dict leaves
    flat_p, treedef = jax.tree.flatten(params_abs)
    flat_spec = treedef.flatten_up_to(pspecs)
    flat_state = treedef.flatten_up_to(opt_state_abs)

    def reduce_spec(spec: P, pshape, sshape) -> P:
        if tuple(sshape) == tuple(pshape):
            return spec
        entries = list(spec) + [None] * (len(pshape) - len(spec))
        if len(sshape) == len(pshape) - 1 and tuple(sshape) == tuple(pshape[:-1]):
            return P(*entries[:-1])  # vr: row stats (last axis reduced)
        if len(sshape) == len(pshape) - 1 and tuple(sshape) == tuple(pshape[:-2] + pshape[-1:]):
            return P(*(entries[:-2] + entries[-1:]))  # vc: col stats
        return P()

    out = []
    for p, spec, st in zip(flat_p, flat_spec, flat_state):
        out.append({k: reduce_spec(spec, p.shape, v.shape) for k, v in st.items()})
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def build_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    cell: ShapeCell,
    *,
    lr: float = 3e-4,
    total_steps: int = 10_000,
    grad_clip: float = 1.0,
    strategy: str = "tp",
) -> StepBundle:
    B, S = cell.global_batch, cell.seq_len
    model = build_model(cfg, mesh, B, strategy=strategy)
    optimizer = make_optimizer(cfg.optimizer, cosine_schedule(lr, 100, total_steps))

    def train_step(params, opt_state, step, tokens, frontend=None):
        def loss_fn(p):
            loss, metrics = model.forward_train(p, tokens, frontend)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, step + 1, metrics

    params_abs = _abstract_params(cfg)
    opt_abs = jax.eval_shape(optimizer.init, params_abs)
    p_shard = param_shardings(params_abs, mesh, strategy=strategy)
    o_pspec = opt_state_pspecs(opt_abs, params_abs, mesh, strategy=strategy)
    o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_pspec)
    bspec = batch_spec(B, mesh, extra_dims=1)
    if strategy == "fsdp":
        # pure data parallelism over the whole mesh: batch over every axis
        axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if B % total == 0:
            bspec = P(axes, *([None]))
    tok_shard = NamedSharding(mesh, bspec)
    step_shard = NamedSharding(mesh, P())

    abstract = [
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), params_abs, p_shard),
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), opt_abs, o_shard),
        jax.ShapeDtypeStruct((), jnp.int32, sharding=step_shard),
        jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_shard),
    ]
    in_shardings = [p_shard, o_shard, step_shard, tok_shard]
    if cfg.frontend:
        f_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=2))
        abstract.append(
            jax.ShapeDtypeStruct((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16, sharding=f_shard)
        )
        in_shardings.append(f_shard)

    metric_shard = jax.tree.map(
        lambda _: step_shard, {"loss": 0, "ce": 0, "lb_loss": 0, "z_loss": 0, "grad_norm": 0}
    )
    out_shardings = (p_shard, o_shard, step_shard, metric_shard)

    return StepBundle(
        name="train_step",
        fn=train_step,
        in_shardings=tuple(in_shardings),
        out_shardings=out_shardings,
        abstract_inputs=tuple(abstract),
        donate_argnums=(0, 1),
        model=model,
    )


# ---------------------------------------------------------------------------
# prefill / serve
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, cell: ShapeCell) -> StepBundle:
    B, S = cell.global_batch, cell.seq_len
    model = build_model(cfg, mesh, B)

    def prefill_step(params, tokens, cache, frontend=None):
        return model.prefill(params, tokens, cache, frontend)

    params_abs = _abstract_params(cfg)
    p_shard = param_shardings(params_abs, mesh)
    cache_abs = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    c_shard = cache_shardings(cache_abs, B, mesh)
    tok_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=1))

    abstract = [
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), params_abs, p_shard),
        jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_shard),
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), cache_abs, c_shard),
    ]
    in_shardings = [p_shard, tok_shard, c_shard]
    if cfg.frontend:
        f_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=2))
        abstract.append(
            jax.ShapeDtypeStruct((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16, sharding=f_shard)
        )
        in_shardings.append(f_shard)

    logits_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=1))
    out_shardings = (logits_shard, c_shard)

    return StepBundle(
        name="prefill_step",
        fn=prefill_step,
        in_shardings=tuple(in_shardings),
        out_shardings=out_shardings,
        abstract_inputs=tuple(abstract),
        donate_argnums=(2,),
        model=model,
    )


def build_serve_step(cfg: ModelConfig, mesh: Mesh, cell: ShapeCell) -> StepBundle:
    """One decode step: one new token per sequence against a seq_len cache.

    ``cfg.decode_plane`` selects the Agile decode plane (DecodePlan slots in
    the cache, capacity-sort-free MoE dispatch, valid-prefix attention).  It
    changes the cache pytree this bundle shards/donates (plan slots per MoE
    layer), so the prefill bundle that seeds the cache MUST be built from a
    config with the same ``decode_plane`` setting — set it on ``cfg`` before
    building either bundle (as launch/serve.py does), never on one side only.
    """
    B, S = cell.global_batch, cell.seq_len
    model = build_model(cfg, mesh, B)

    def serve_step(params, cache, tokens, cache_index):
        return model.decode_step(params, cache, tokens, cache_index)

    params_abs = _abstract_params(cfg)
    p_shard = param_shardings(params_abs, mesh)
    cache_abs = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    c_shard = cache_shardings(cache_abs, B, mesh)
    tok_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=0))
    scalar_shard = NamedSharding(mesh, P())

    abstract = (
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), params_abs, p_shard),
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), cache_abs, c_shard),
        jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tok_shard),
        jax.ShapeDtypeStruct((), jnp.int32, sharding=scalar_shard),
    )
    logits_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=1))
    out_shardings = (logits_shard, c_shard)

    return StepBundle(
        name="serve_step",
        fn=serve_step,
        in_shardings=tuple(x for x in (p_shard, c_shard, tok_shard, scalar_shard)),
        out_shardings=out_shardings,
        abstract_inputs=abstract,
        donate_argnums=(1,),
        model=model,
    )


def build_spec_serve_step(
    cfg: ModelConfig, mesh: Mesh, cell: ShapeCell, *, telemetry: bool = False,
    tree: Optional[Any] = None,
) -> StepBundle:
    """One speculative/ragged serve launch: T = ``cfg.spec_tokens`` tokens per
    sequence against per-sequence cache lengths (continuous batching).

    The launch signature is ``(params, cache, tokens (B, T), lengths (B,),
    prev_accept (B,))`` -> ``(logits (B, T, V), cache[, metrics])`` —
    ``prev_accept`` selects each sequence's cache-carried plan row (the one
    computed from the route source of the position the previous launch's
    verification accepted).  As with ``build_serve_step``, the prefill bundle
    seeding the cache must be built from a config with identical
    ``decode_plane``/``spec_tokens`` settings (the plan-vector slots are part
    of the cache pytree).

    ``tree`` (a :class:`repro.core.plans.TreePlan` with ``num_nodes ==
    spec_tokens``) turns each launch into a draft-tree launch: the topology
    is compiled into the step closure (static under jit), the verifier walks
    it host-side, and ``prev_accept`` becomes the accepted node index.

    Under ``cfg.paged`` the launch takes one more control word: ``pages``,
    the replicated (B, max_pages) int32 block table.  A BRANCHY tree launch
    additionally takes the previous verify round's fused commit maps
    ``(dst, src)`` — the chain/no-tree step statically omits them, which is
    what keeps the paged chain path bitwise-identical to the contiguous one
    (no commit gather/scatter ever enters the compiled graph).
    """
    B, S = cell.global_batch, cell.seq_len
    Tn = max(cfg.spec_tokens, 1)
    if tree is not None and tree.num_nodes != Tn:
        raise ValueError(
            f"tree has {tree.num_nodes} nodes but cfg.spec_tokens is {Tn}"
        )
    model = build_model(cfg, mesh, B)
    branchy = tree is not None and not tree.is_chain()

    if cfg.paged and branchy:
        def spec_step(params, cache, tokens, lengths, prev_accept, pages, dst, src):
            return model.decode_tokens(
                params, cache, tokens, lengths, prev_accept, telemetry=telemetry,
                tree=tree, pages=pages, commit=(dst, src),
            )
    elif cfg.paged:
        def spec_step(params, cache, tokens, lengths, prev_accept, pages):
            return model.decode_tokens(
                params, cache, tokens, lengths, prev_accept, telemetry=telemetry,
                tree=tree, pages=pages,
            )
    else:
        def spec_step(params, cache, tokens, lengths, prev_accept):
            return model.decode_tokens(
                params, cache, tokens, lengths, prev_accept, telemetry=telemetry,
                tree=tree,
            )

    params_abs = _abstract_params(cfg)
    p_shard = param_shardings(params_abs, mesh)
    cache_abs = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    c_shard = cache_shardings(cache_abs, B, mesh)
    tok_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=1))
    vec_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=0))
    rep_shard = NamedSharding(mesh, P())

    abstract = [
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), params_abs, p_shard),
        jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), cache_abs, c_shard),
        jax.ShapeDtypeStruct((B, Tn), jnp.int32, sharding=tok_shard),
        jax.ShapeDtypeStruct((B,), jnp.int32, sharding=vec_shard),
        jax.ShapeDtypeStruct((B,), jnp.int32, sharding=vec_shard),
    ]
    in_shardings = [p_shard, c_shard, tok_shard, vec_shard, vec_shard]
    if cfg.paged:
        # the block table is a control word: replicated, like the plan scalars
        mp = T.max_pages_for(cfg, S)
        abstract.append(jax.ShapeDtypeStruct((B, mp), jnp.int32, sharding=rep_shard))
        in_shardings.append(rep_shard)
        if branchy:
            for _ in ("dst", "src"):
                abstract.append(
                    jax.ShapeDtypeStruct((B, Tn), jnp.int32, sharding=tok_shard)
                )
                in_shardings.append(tok_shard)
    logits_shard = NamedSharding(mesh, batch_spec(B, mesh, extra_dims=2))
    out_shardings = (logits_shard, c_shard)
    if telemetry:
        out_shardings = out_shardings + ({"plan_agreement": NamedSharding(mesh, P())},)

    return StepBundle(
        name="spec_serve_step",
        fn=spec_step,
        in_shardings=tuple(in_shardings),
        out_shardings=out_shardings,
        abstract_inputs=tuple(abstract),
        donate_argnums=(1,),
        model=model,
    )


@dataclasses.dataclass
class AdmissionBundle:
    """The jitted B=1 admission path shared by every serve replica.

    Admission prefill runs at batch 1 (batch replicated; KV heads stay
    model-sharded) through a model whose collectives are built for batch=1 —
    the serve model's batch axes need not divide 1 — and ``admit`` writes the
    prefilled slot into the batch cache sharding-preservingly (donated
    ``dynamic_update_slice``, no host round trip).
    """

    prefill: Callable        # (params, tokens (1, L), one_cache[, frontend])
    one_cache_init: Callable  # () -> fresh B=1 cache allocated on the mesh
    admit: Callable          # (batch_cache, one_cache, slot) -> batch_cache
    model: Model             # the B=1 prefill model


def build_admission(
    cfg: ModelConfig, mesh: Mesh, serve_model: Model, max_len: int, cache_sharding: Any
) -> AdmissionBundle:
    """Under ``cfg.paged`` the B=1 prefill runs CONTIGUOUS (``paged=False``
    twin config — prefill writes stripes) and ``admit`` becomes the paged
    scatter :meth:`~repro.models.model.Model.write_cache_slot_paged`:
    ``admit(batch_cache, one_cache, slot, rows)`` with the host-computed
    physical-row vector — trie-shared pages send sentinel rows, so a
    trie-resident prompt admits with zero KV copies."""
    pf_cfg = dataclasses.replace(cfg, paged=False) if cfg.paged else cfg
    pf_model = build_model(pf_cfg, mesh, 1)
    c1_abs = jax.eval_shape(lambda: T.init_cache(pf_cfg, 1, max_len))
    c1_shard = cache_shardings(c1_abs, 1, mesh)
    lg1_shard = NamedSharding(mesh, batch_spec(1, mesh, extra_dims=1))
    prefill = jax.jit(pf_model.prefill, out_shardings=(lg1_shard, c1_shard))
    one_cache_init = jax.jit(
        lambda: T.init_cache(pf_cfg, 1, max_len), out_shardings=c1_shard
    )
    admit_fn = (
        serve_model.write_cache_slot_paged if cfg.paged else serve_model.write_cache_slot
    )
    admit = jax.jit(
        admit_fn, donate_argnums=(0,), out_shardings=cache_sharding
    )
    return AdmissionBundle(
        prefill=prefill, one_cache_init=one_cache_init, admit=admit, model=pf_model
    )


def build_step(cfg: ModelConfig, mesh: Mesh, cell: ShapeCell, *, strategy: str = "tp") -> StepBundle:
    if cell.step == "train":
        return build_train_step(cfg, mesh, cell, strategy=strategy)
    if cell.step == "prefill":
        return build_prefill_step(cfg, mesh, cell)
    if cell.step == "decode":
        return build_serve_step(cfg, mesh, cell)
    raise ValueError(cell.step)
