"""CS-Benes control network model (paper §4.1, Fig. 6/13, Table 6).

The control network is a Benes rearrangeable non-blocking permutation network
augmented with a Consecutive-Spreading (CS) broadcast stage.  This module
models its structure (stage/switch counts), synthesis behaviour (Fig. 13:
combinational delay vs. clock target => pipelined network latency), and area
(Table 6: the 11.5% network-to-fabric ratio), with constants calibrated to
the paper's 28nm synthesis numbers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

# -- 28nm calibration constants ----------------------------------------------
# A 16-endpoint CS-Benes control network synthesizes to 0.0022 mm^2 (Table 4).
SWITCH_AREA_MM2 = 2.5e-5       # one 2x2 switch incl. config bit + wiring share
SWITCH_DELAY_NS = 0.16         # combinational delay through one switch stage
WIRE_DELAY_NS = 0.05           # inter-stage wire delay
CTRL_WIDTH_BITS = 16           # instruction-address control words (not data!)

# Data-network calibration (32-bit words, 4x4 mesh): Table 4's 0.0063 mm^2
# over 2*4*3 + 2*4 = 32 bidirectional mesh + edge-I/O links.
DATA_NOC_AREA_PER_LINK_MM2 = 1.97e-4
MEM_XCONNECT_AREA_MM2 = 0.003


def benes_stages(n: int) -> int:
    """Benes(N): 2*log2(N) - 1 switch stages."""
    if n < 2 or n & (n - 1):
        raise ValueError("Benes network size must be a power of two >= 2")
    return 2 * int(math.log2(n)) - 1


def cs_stages(n: int) -> int:
    """Consecutive-Spreading broadcast stage count: log2(N)."""
    return int(math.log2(n))


def total_stages(n: int) -> int:
    return benes_stages(n) + cs_stages(n)


def switch_count(n: int) -> int:
    """2x2 switches: N/2 per stage across Benes + CS stages."""
    return (n // 2) * total_stages(n)


def control_network_area(n: int) -> float:
    """mm^2 at 28nm for an N-endpoint CS-Benes control network."""
    return switch_count(n) * SWITCH_AREA_MM2


def crossbar_area(n: int) -> float:
    """The rejected alternative: full crossbar crosspoint count x switch area."""
    return n * n * SWITCH_AREA_MM2


def combinational_delay_ns(n: int) -> float:
    s = total_stages(n)
    return s * SWITCH_DELAY_NS + (s - 1) * WIRE_DELAY_NS


def network_latency_cycles(n: int, freq_mhz: float) -> int:
    """Fig. 13: pipeline registers are inserted to meet the clock target, so
    latency (cycles) = ceil(combinational delay / clock period)."""
    period_ns = 1e3 / freq_mhz
    return max(1, math.ceil(combinational_delay_ns(n) / period_ns))


def scaling_table(
    sizes: Tuple[int, ...] = (8, 16, 32, 64, 128),
    freqs_mhz: Tuple[float, ...] = (250.0, 500.0, 1000.0, 2000.0),
) -> List[Dict[str, float]]:
    """Fig. 13 reproduction: stages / delay / critical path across sizes+clocks."""
    rows = []
    for n in sizes:
        for f in freqs_mhz:
            rows.append(
                {
                    "endpoints": n,
                    "stages": total_stages(n),
                    "freq_mhz": f,
                    "comb_delay_ns": round(combinational_delay_ns(n), 3),
                    "latency_cycles": network_latency_cycles(n, f),
                    "critical_path_ns": round(
                        min(combinational_delay_ns(n), 1e3 / f), 3
                    ),
                }
            )
    return rows


# ---------------------------------------------------------------------------
# Table 6: network area vs. state-of-the-art (normalized 28nm, 32-bit, 4x4)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NetworkArea:
    name: str
    pe_area: float
    network_area: float

    @property
    def fabric_area(self) -> float:
        return self.pe_area + self.network_area

    @property
    def network_ratio(self) -> float:
        return self.network_area / self.fabric_area


# Published normalized areas of the comparison architectures (paper Table 6).
PAPER_TABLE6: Dict[str, NetworkArea] = {
    "softbrain": NetworkArea("softbrain", 0.0041, 0.0130),
    "revel": NetworkArea("revel", 0.022, 0.028),
    "dyser": NetworkArea("dyser", 0.058, 0.052),
    "plasticine": NetworkArea("plasticine", 0.161, 0.294),
    "spu": NetworkArea("spu", 0.050, 0.045),
    "marionette": NetworkArea("marionette", 0.0908, 0.0118),
}


def marionette_network_area_model(n_pes: int = 16) -> Dict[str, float]:
    """Analytic model of Marionette's network area: data mesh + memory
    interconnect + CS-Benes control network.  For the 4x4 fabric this should
    land on Table 6's 0.0118 mm^2 (the 11.5% ratio)."""
    side = int(math.isqrt(n_pes))
    mesh_links = 2 * side * (side - 1) + 2 * side  # bidirectional mesh + edge I/O
    data = mesh_links * DATA_NOC_AREA_PER_LINK_MM2
    ctrl = control_network_area(n_pes)
    mem = MEM_XCONNECT_AREA_MM2 * (n_pes / 16)
    return {
        "data_network": data,
        "control_network": ctrl,
        "memory_interconnect": mem,
        "total": data + ctrl + mem,
    }


def table6_rows() -> List[Dict[str, object]]:
    """Model-vs-paper rows for the Table 6 benchmark."""
    model_total = marionette_network_area_model()["total"]
    rows: List[Dict[str, object]] = []
    for name, a in PAPER_TABLE6.items():
        net = model_total if name == "marionette" else a.network_area
        fabric = a.pe_area + net
        rows.append(
            {
                "arch": name,
                "pe_area_mm2": a.pe_area,
                "network_area_mm2": round(net, 4),
                "fabric_area_mm2": round(fabric, 4),
                "network_ratio": round(net / fabric, 3),
                "paper_network_area_mm2": a.network_area,
                "paper_ratio": round(a.network_ratio, 3),
            }
        )
    return rows
