"""Architecture models (paper §6.1): parameter sets for the timing engine.

The three PE execution models (Fig. 2/4) and the four SOTA comparison
architectures (Softbrain, TIA, REVEL, RipTide), normalized to the same
16-PE computing fabric (Table 4 / §6.1).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict


@dataclass(frozen=True)
class ArchModel:
    name: str
    # PE execution model ----------------------------------------------------
    pe_model: str          # von_neumann | dataflow | marionette | hybrid
    ii_base: int           # pipeline II floor per PE (dataflow: tag+config per firing)
    branch_style: str      # predication | switch | tag | proactive | network_ops
    # control flow transport --------------------------------------------------
    ctrl_transport: str    # ccu | data_noc | benes | network_ops
    ctrl_delay: int        # cycles per control-flow transfer
    config_switch: int     # non-overlapped cycles to reconfigure a PE group
    proactive: bool        # next-stage config overlaps current compute
    # scheduling ---------------------------------------------------------------
    agile: bool            # Agile PE Assignment (fold outer BBs + replicate inner)
    overlap_outer: bool    # outer BB pipeline runs concurrently with inner (FIFOs)
    inner_replicas_cap: int  # max replication of inner pipelines (0 = unlimited)
    outer_fabric_pes: int  # PEs reserved for outer BBs (REVEL: 1 dataflow PE); 0 = shared
    serial_reconfig: bool = False  # systolic fabrics re-configure per serial iteration
    n_pes: int = 16


# -- the three PE models of Fig. 11 (unified data network, no ctrl net, no agile)
von_neumann_pe = ArchModel(
    name="von-neumann-pe", pe_model="von_neumann", ii_base=1,
    branch_style="predication", ctrl_transport="ccu", ctrl_delay=8,
    config_switch=4, proactive=False, agile=False, overlap_outer=False,
    inner_replicas_cap=1, outer_fabric_pes=0,
)

dataflow_pe = ArchModel(
    name="dataflow-pe", pe_model="dataflow", ii_base=2,
    branch_style="tag", ctrl_transport="data_noc", ctrl_delay=4,
    config_switch=2, proactive=False, agile=False, overlap_outer=False,
    inner_replicas_cap=1, outer_fabric_pes=0,
)

marionette_pe = ArchModel(  # Proactive PE Configuration only (Fig. 11 setting)
    name="marionette-pe", pe_model="marionette", ii_base=1,
    branch_style="proactive", ctrl_transport="data_noc", ctrl_delay=4,
    config_switch=0, proactive=True, agile=False, overlap_outer=False,
    inner_replicas_cap=1, outer_fabric_pes=0,
)

marionette_net = replace(  # + CS-Benes peer-to-peer control network (Fig. 12)
    marionette_pe, name="marionette-net", ctrl_transport="benes", ctrl_delay=1,
)

marionette = replace(  # + Agile PE Assignment (Fig. 14) = full Marionette
    marionette_net, name="marionette", agile=True, overlap_outer=True,
    inner_replicas_cap=0,
)

# -- SOTA models (§6.1) -------------------------------------------------------
softbrain = ArchModel(
    # Stream-dataflow: vN PEs + stream engine; II=1 pipelines, predication,
    # CCU-mediated config, static mapping (no agile).
    name="softbrain", pe_model="von_neumann", ii_base=1,
    branch_style="predication", ctrl_transport="ccu", ctrl_delay=8,
    config_switch=4, proactive=False, agile=False, overlap_outer=False,
    inner_replicas_cap=1, outer_fabric_pes=0,
)

tia = ArchModel(
    # Triggered instructions: dataflow PEs, autonomous triggers (no CCU) but
    # per-firing trigger resolution lengthens II; control rides data channels.
    name="tia", pe_model="dataflow", ii_base=2,
    branch_style="tag", ctrl_transport="data_noc", ctrl_delay=4,
    config_switch=2, proactive=False, agile=False, overlap_outer=False,
    inner_replicas_cap=1, outer_fabric_pes=0,
)

revel = ArchModel(
    # Hybrid systolic-dataflow: inner loops on 15 systolic PEs (II=1),
    # outer BBs on 1 tagged-dataflow PE; stream-decoupled (partial overlap).
    # Systolic PEs cannot fire data-driven: serial loops re-issue their
    # stream configuration every iteration (serial_reconfig).
    name="revel", pe_model="hybrid", ii_base=1,
    branch_style="predication", ctrl_transport="data_noc", ctrl_delay=4,
    config_switch=2, proactive=False, agile=True, overlap_outer=True,
    inner_replicas_cap=0, outer_fabric_pes=1, serial_reconfig=True,
)

riptide = ArchModel(
    # Energy-minimal dataflow compiler: control operators placed in the NoC;
    # no CCU round trips, but in-network control transfer is slow and the
    # control ops steal network bandwidth (coupled control/data).
    name="riptide", pe_model="von_neumann", ii_base=1,
    branch_style="network_ops", ctrl_transport="network_ops", ctrl_delay=4,
    config_switch=0, proactive=False, agile=False, overlap_outer=False,
    inner_replicas_cap=1, outer_fabric_pes=0,
)

ARCHS: Dict[str, ArchModel] = {
    a.name: a
    for a in [
        von_neumann_pe, dataflow_pe, marionette_pe, marionette_net, marionette,
        softbrain, tia, revel, riptide,
    ]
}
