"""Cycle-level timing engine: walks a Workload loop tree under an ArchModel.

Timing semantics follow the paper's Fig. 3/7 timelines:

* Fully-pipelined innermost loops run at II = max(ii_min, ii_base) x mux (+
  branch steering penalties), where mux is the time-multiplex fold when the
  spatial footprint exceeds the fabric.
* Partially-pipelined (serial) loops pay, per iteration: the body critical
  path, one control-flow transfer (CCU round trip for von Neumann PEs, data
  NoC hops for dataflow PEs, one CS-Benes hop for Marionette), and the
  branch-resolution cost of the model's branch style.
* Divergent branches: predication consumes both-path PEs (footprint); tag
  steering resolves on the data path (serial chain cost + nested transfers);
  in-network control ops serialize a hop; proactive configuration overlaps
  the next-stage config with current-stage compute (zero exposed cost).
* Imperfect loops serialize outer-BB work, control transfer, and inner loop
  per outer iteration — except when the model overlaps them (Marionette's
  Control FIFOs, REVEL's stream decoupling).
* Agile PE Assignment folds outer BBs into few PEs (time-extension) and
  replicates parallel inner pipelines over the spare fabric (Fig. 8/15).
  Single-level parallel loops are statically unrolled by EVERY architecture
  (spatial replication needs no control flow), which is why Fig. 17 shows
  near-identical performance on the non-intensive benchmarks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.sim.archs import ArchModel
from repro.sim.workload import Branch, Loop, Workload

OUTER_FOLD_PES = 2  # time-extension target for rarely-executing outer BBs


@dataclass(frozen=True)
class SimResult:
    benchmark: str
    arch: str
    cycles: float
    footprint: int
    mux: int
    inner_replicas: int
    outer_util: float      # utilization of PEs hosting outer-loop BBs
    pipe_util: float       # ideal II / achieved II of the main pipeline
    work: float            # total dynamic ops

    @property
    def ops_per_cycle(self) -> float:
        return self.work / self.cycles if self.cycles else 0.0


# ---------------------------------------------------------------------------
# footprint
# ---------------------------------------------------------------------------


def loop_footprint(l: Loop, model: ArchModel) -> int:
    f = l.ops
    if l.branch:
        if model.branch_style == "predication":
            f += l.branch.both_ops  # both lanes mapped spatially
        else:
            f += max(l.branch.taken_ops, l.branch.not_taken_ops)  # one lane
    return f


def workload_footprint(w: Workload, model: ArchModel) -> int:
    return sum(loop_footprint(l, model) for l in w.all_loops())


# ---------------------------------------------------------------------------
# branch handling costs
# ---------------------------------------------------------------------------


def _branch_pipelined(b: Optional[Branch], model: ArchModel) -> float:
    """Cycles added to a *pipelined* loop's II per iteration by branches."""
    if b is None:
        return 0.0
    style = model.branch_style
    if style in ("predication", "proactive"):
        return 0.0  # spatial / pre-configured: no exposed time
    if style == "tag":
        return float(b.nested)  # nested divergence re-steers on data channels
    if style == "network_ops":
        return 0.5 * (1 + b.nested)  # in-network steering hop per resolution
    raise ValueError(style)


def _branch_serial(b: Optional[Branch], model: ArchModel) -> float:
    """Branch cost on the critical chain of a *serial* (non-pipelined) loop."""
    if b is None:
        return 0.0
    style = model.branch_style
    if style == "predication":
        return 2.0 * b.nested  # nested divergence needs a second select wave
    if style == "proactive":
        return 0.0  # both targets pre-configured during compute
    if style == "tag":
        return 1.0 + b.nested  # per-firing tag resolution + nested transfers
    if style == "network_ops":
        return 1.0 + b.nested
    raise ValueError(style)


def _ctrl_transfer(model: ArchModel) -> float:
    """One control-flow transfer between PE groups."""
    return float(model.ctrl_delay)


# ---------------------------------------------------------------------------
# agile assignment: fold outer BBs, replicate inner pipelines
# ---------------------------------------------------------------------------


def _main_inner(w: Workload) -> Loop:
    """The innermost loop carrying the most dynamic work."""
    inners = [l for l in w.all_loops() if l.is_innermost]

    def dyn_work(l: Loop) -> float:
        return l.body_mean_ops() * _dyn_iters(w.root, l)

    return max(inners, key=dyn_work)


def _dyn_iters(root: Loop, target: Loop, mult: float = 1.0) -> float:
    if root is target:
        return mult * root.trip
    for c in root.children:
        r = _dyn_iters(c, target, mult * root.trip)
        if r:
            return r
    return 0.0


def _replicable(w: Workload, inner: Loop) -> bool:
    """Pipeline replication is legal if the inner loop's iterations are
    independent OR some ancestor's iterations are (replicas then process
    different ancestor iterations — the paper's 'reconfigure outer-BB PEs as
    inner loop pipelines')."""
    if inner.parallel:
        return True

    def path_to(l: Loop, target: Loop) -> Optional[List[Loop]]:
        if l is target:
            return [l]
        for c in l.children:
            p = path_to(c, target)
            if p is not None:
                return [l] + p
        return None

    path = path_to(w.root, inner) or []
    return any(a.parallel for a in path[:-1])


def agile_allocation(w: Workload, model: ArchModel) -> Tuple[int, int, int]:
    """Returns (inner_replicas, folded_other_footprint, mux)."""
    inner = _main_inner(w)
    inner_fp = loop_footprint(inner, model)
    others = [l for l in w.all_loops() if l is not inner]
    folded = sum(min(loop_footprint(l, model), OUTER_FOLD_PES) for l in others)
    avail = model.n_pes - folded
    if avail < inner_fp:
        return 1, folded, max(1, math.ceil((inner_fp + folded) / model.n_pes))
    replicas = 1
    if inner.pipelineable and _replicable(w, inner):
        replicas = max(1, avail // max(inner_fp, 1))
        if model.inner_replicas_cap:
            replicas = min(replicas, model.inner_replicas_cap)
    return replicas, folded, 1


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------


def simulate(w: Workload, model: ArchModel) -> SimResult:
    F = workload_footprint(w, model)
    inner = _main_inner(w)

    # Single-level parallel loops: static spatial unrolling, available to every
    # architecture (no dynamic control flow involved).
    static_unroll = w.root.is_innermost and w.root.parallel and w.root.pipelineable

    if static_unroll:
        replicas = max(1, model.n_pes // max(F, 1))
        mux = max(1, math.ceil(F / model.n_pes))
    elif model.agile and not model.outer_fabric_pes:
        replicas, _folded, mux = agile_allocation(w, model)
    elif model.outer_fabric_pes:
        # REVEL: inner loops on the systolic sub-fabric, outer BBs folded onto
        # the small dataflow sub-fabric.
        inner_fp = loop_footprint(inner, model)
        inner_pes = model.n_pes - model.outer_fabric_pes
        others_fp = sum(loop_footprint(l, model) for l in w.all_loops() if l is not inner)
        replicas = (
            max(1, inner_pes // max(inner_fp, 1))
            if (inner.pipelineable and _replicable(w, inner))
            else 1
        )
        if model.inner_replicas_cap:
            replicas = min(replicas, model.inner_replicas_cap)
        mux = max(1, math.ceil(others_fp / max(model.outer_fabric_pes * 4, 1)))
    else:
        replicas, mux = 1, max(1, math.ceil(F / model.n_pes))

    cycles = _timed_root(w, model, mux, replicas)

    ideal_ii = max(inner.ii_min, 1)
    achieved_ii = max(inner.ii_min, model.ii_base) * mux + _branch_pipelined(inner.branch, model)
    if inner.pipelineable:
        pipe_util = min(1.0, ideal_ii / achieved_ii)
    else:
        pipe_util = ideal_ii / (inner.depth + _ctrl_transfer(model))

    outer_work = sum(
        l.body_mean_ops() * _dyn_iters(w.root, l) for l in w.all_loops() if not l.is_innermost
    )
    outer_pes = (
        sum(min(loop_footprint(l, model), OUTER_FOLD_PES) for l in w.all_loops() if not l.is_innermost)
        if model.agile
        else sum(loop_footprint(l, model) for l in w.all_loops() if not l.is_innermost)
    )
    outer_util = min(1.0, outer_work / (max(outer_pes, 1) * cycles)) if cycles else 0.0

    return SimResult(
        benchmark=w.name,
        arch=model.name,
        cycles=cycles,
        footprint=F,
        mux=mux,
        inner_replicas=replicas,
        outer_util=outer_util,
        pipe_util=pipe_util,
        work=w.root.total_work(),
    )


def _timed_root(w: Workload, model: ArchModel, mux: int, replicas: int) -> float:
    """Walk the tree threading the main-inner replication to the dominant loop."""
    main = _main_inner(w)

    def rec(l: Loop) -> float:
        child_t = sum(rec(c) for c in l.children)
        if l.is_innermost:
            r = replicas if l is main else 1
            if l.pipelineable:
                ii = max(l.ii_min, model.ii_base) * mux + _branch_pipelined(l.branch, model)
                return l.depth + ii * max(math.ceil(l.trip / r) - 1, 0)
            # Partially pipelined: every iteration exposes its critical path,
            # one control transfer, and the branch-resolution cost.
            per_iter = (
                l.depth
                + _ctrl_transfer(model)
                + _branch_serial(l.branch, model)
                + (model.ii_base - 1)  # per-firing config (dataflow tokens)
                + 2 * (mux - 1)
                + (model.config_switch if model.serial_reconfig else 0)
            )
            return (l.trip / r if l is main and _replicable(w, l) else l.trip) * per_iter
        t_body = (
            l.depth + _branch_serial(l.branch, model) + 2 * (mux - 1)
            if (l.ops or l.branch)
            else 0.0
        )
        if model.overlap_outer:
            # Control FIFOs: outer-BB control is pre-collected; the inner
            # pipeline re-initiates without waiting on the outer BB.
            per_iter = max(t_body, child_t) + model.ctrl_delay
        else:
            per_iter = t_body + _ctrl_transfer(model) + child_t
            if model.ctrl_transport == "ccu":
                per_iter += model.config_switch  # CCU re-issues inner config
            elif model.pe_model == "von_neumann" and mux > 1:
                per_iter += model.config_switch  # reconfig between folds
        return l.trip * per_iter

    return rec(w.root)
