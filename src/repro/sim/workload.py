"""Benchmark workload representation: loop trees with branch structure.

A Workload is a tree of Loops; each Loop iteration executes its body DFG
(``ops``/``depth``), optional divergent Branch paths, and invokes its child
loops.  Trip counts come from the paper's Table-5 data sizes, op counts from
the benchmark kernels' inner-loop DFGs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class Branch:
    """A divergent conditional inside a loop body.

    taken_ops / not_taken_ops: DFG size of the two target BBs.
    p_taken: dynamic probability of the taken path.
    nested: extra nesting depth of branches (nested branches add control
    transfers per resolution).
    """

    taken_ops: int
    not_taken_ops: int
    p_taken: float = 0.5
    nested: int = 0

    @property
    def mean_ops(self) -> float:
        return self.p_taken * self.taken_ops + (1 - self.p_taken) * self.not_taken_ops

    @property
    def both_ops(self) -> int:
        return self.taken_ops + self.not_taken_ops


@dataclass(frozen=True)
class Loop:
    """One loop level.

    trip          iterations per parent invocation
    ops           non-branch body DFG ops executed every iteration at this level
    depth         body DFG critical-path depth
    branch        optional divergent branch in the body
    children      nested loops invoked once per iteration (imperfect if ops>0)
    ii_min        data-dependence-limited initiation interval
    pipelineable  iterations can overlap (False => loop-carried serial body)
    parallel      iterations independent => pipeline replication is legal
    """

    name: str
    trip: int
    ops: int = 0
    depth: int = 4
    branch: Optional[Branch] = None
    children: tuple = ()
    ii_min: int = 1
    pipelineable: bool = True
    parallel: bool = True

    @property
    def is_innermost(self) -> bool:
        return not self.children

    def body_mean_ops(self) -> float:
        b = self.branch.mean_ops if self.branch else 0.0
        return self.ops + b

    def total_iterations(self) -> int:
        """Dynamic iterations of the innermost descendants."""
        if self.is_innermost:
            return self.trip
        return self.trip * sum(c.total_iterations() for c in self.children)

    def total_work(self) -> float:
        w = self.trip * self.body_mean_ops()
        for c in self.children:
            w += self.trip * c.total_work()
        return w


@dataclass(frozen=True)
class Workload:
    """A benchmark: its loop tree + classification flags used in the paper.

    intensive: counted in the "intensive control flow" geomeans (Fig. 17
    excludes Conv-1d / Sigmoid / Gray from the intensive geomean).
    """

    name: str
    root: Loop
    intensive: bool = True

    def all_loops(self) -> List[Loop]:
        out: List[Loop] = []

        def rec(l: Loop) -> None:
            out.append(l)
            for c in l.children:
                rec(c)

        rec(self.root)
        return out

    @property
    def has_branch(self) -> bool:
        return any(l.branch is not None for l in self.all_loops())

    @property
    def nest_depth(self) -> int:
        def rec(l: Loop) -> int:
            return 1 + max((rec(c) for c in l.children), default=0)

        return rec(self.root)

    def branch_op_fraction(self) -> float:
        """Fraction of dynamic ops that live under divergent branches —
        the paper's "proportion of operators under the branch" (Fig. 11)."""
        under, total = 0.0, 0.0

        def rec(l: Loop, iters: float) -> None:
            nonlocal under, total
            it = iters * l.trip
            total += it * l.body_mean_ops()
            if l.branch:
                under += it * l.branch.mean_ops
            for c in l.children:
                rec(c, it)

        rec(self.root, 1.0)
        return under / total if total else 0.0
