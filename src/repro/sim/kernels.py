"""The paper's 13 evaluation benchmarks (Table 5) as Workload loop trees.

Loop/branch structure follows Table 1's qualitative classification; op and
depth counts come from the kernels' innermost-loop DFGs (MachSuite / MiBench
/ HosNa sources); trip counts are the exact Table-5 data sizes.

  benchmark        data size                  control flow (Table 1)
  Merge Sort       1024                       nested innermost branches, imperfect nest
  FFT              1024 points                innermost branch, imperfect nest (II=2)
  Viterbi          64 st / 140 obs / 64 tok   imperfect nest (II=2)
  NW               128x128                    nested innermost branches, nest
  Hough Transform  120x180                    sub-inner branch, imperfect nest
  CRC              64 bytes                   innermost branch, serial loops
  ADPCM Encode     2000 bytes                 serial branches
  SC Decode        2048 channels              innermost branch, imperfect nest + serial
  LDPC Decode      20 iters x 128             nested branches, imperfect nest + serial
  GEMM             64x64                      imperfect nest (no branch)
  Conv-1d          16384                      single loop (non-intensive)
  Sigmoid          2048                       single loop (non-intensive)
  Gray Processing  16384                      single loop (non-intensive)
"""
from __future__ import annotations

from typing import Dict

from repro.sim.workload import Branch, Loop, Workload

# ---------------------------------------------------------------------------
# intensive control flow benchmarks
# ---------------------------------------------------------------------------

merge_sort = Workload(
    "merge-sort",
    # log2(1024) = 10 merge passes; each pass streams 1024 elements through a
    # divergent compare-select with nested boundary checks.  The merge pointer
    # advance is loop-carried (ii_min 2) and passes are serial.
    Loop(
        "pass", trip=10, ops=3, depth=4, pipelineable=False, parallel=False,
        children=(
            Loop(
                "merge", trip=1024, ops=2, depth=9, ii_min=2,
                branch=Branch(taken_ops=3, not_taken_ops=3, p_taken=0.5, nested=1),
                pipelineable=False, parallel=False,
            ),
        ),
    ),
)

fft = Workload(
    "fft",
    # 10 butterfly stages; 512 butterflies per stage.  Twiddle-index logic is
    # an innermost branch; the butterfly feeds itself across strides, limiting
    # the practical pipeline to II=2 (paper Fig. 15: 33% utilization).
    Loop(
        "stage", trip=10, ops=3, depth=4, pipelineable=False, parallel=False,
        children=(
            Loop(
                "butterfly", trip=512, ops=6, depth=6, ii_min=2,
                branch=Branch(taken_ops=1, not_taken_ops=1, p_taken=0.5),
                pipelineable=True, parallel=True,
            ),
        ),
    ),
)

viterbi = Workload(
    "viterbi",
    # 140 observations x 64 states x 64 predecessor states; the inner
    # add-compare-select max-reduction is loop-carried (II=2).
    Loop(
        "obs", trip=140, ops=1, depth=3, pipelineable=False, parallel=False,
        children=(
            Loop(
                "state", trip=64, ops=2, depth=4, pipelineable=False, parallel=True,
                children=(
                    Loop(
                        "prev", trip=64, ops=2, depth=5, ii_min=2,
                        branch=Branch(taken_ops=2, not_taken_ops=2, p_taken=0.5),
                        pipelineable=True, parallel=False,
                    ),
                ),
            ),
        ),
    ),
)

nw = Workload(
    "nw",
    # Needleman-Wunsch 128x128 DP; the cell update picks max of three
    # candidates (nested branches); anti-diagonal dependence gives II=2.
    Loop(
        "row", trip=128, ops=2, depth=3, pipelineable=False, parallel=True,
        children=(
            Loop(
                "col", trip=128, ops=4, depth=7, ii_min=2,
                branch=Branch(taken_ops=3, not_taken_ops=2, p_taken=0.5, nested=1),
                pipelineable=True, parallel=False,
            ),
        ),
    ),
)

hough = Workload(
    "hough-transform",
    # 120x180 pixels; the edge threshold is the sub-inner branch; edge pixels
    # vote across 180 theta bins (independent -> replicable pipeline).
    Loop(
        "pixel", trip=21_600, ops=2, depth=4,
        branch=Branch(taken_ops=2, not_taken_ops=1, p_taken=0.25),
        pipelineable=False, parallel=True,
        children=(
            Loop(
                "theta", trip=180, ops=2, depth=5, ii_min=2,
                pipelineable=True, parallel=True,
            ),
        ),
    ),
)

crc = Workload(
    "crc",
    # 64 input bytes x 8 bits; the polynomial-xor branch depends on the MSB of
    # the running remainder -> fully serial (no pipelining).
    Loop(
        "byte", trip=64, ops=2, depth=3, pipelineable=False, parallel=False,
        children=(
            Loop(
                "bit", trip=8, ops=3, depth=7,
                branch=Branch(taken_ops=2, not_taken_ops=1, p_taken=0.5),
                pipelineable=False, parallel=False,
            ),
        ),
    ),
)

adpcm = Workload(
    "adpcm",
    # 2000 samples; step-size adaptation is a chain of serial branches on the
    # loop-carried predictor state -> serial.
    Loop(
        "sample", trip=2000, ops=8, depth=12,
        branch=Branch(taken_ops=4, not_taken_ops=3, p_taken=0.5, nested=1),
        pipelineable=False, parallel=False,
    ),
)

sc_decode = Workload(
    "sc-decode",
    # Polar successive-cancellation, 2048 channels: 11 serial tree stages;
    # within a stage the f/g node updates (innermost branch) are independent.
    Loop(
        "stage", trip=11, ops=3, depth=4, pipelineable=False, parallel=False,
        children=(
            Loop(
                "node", trip=1024, ops=2, depth=5, ii_min=1,
                branch=Branch(taken_ops=1, not_taken_ops=1, p_taken=0.5),
                pipelineable=True, parallel=True,
            ),
        ),
    ),
)

ldpc = Workload(
    "ldpc",
    # 20 decoding iterations (serial); 128 check nodes; 6-edge min-sum update
    # with nested compare branches.  Inter-iteration dependences limit
    # replication (paper: LDPC gains are bounded by loop-carried deps).
    Loop(
        "iter", trip=20, ops=2, depth=3, pipelineable=False, parallel=False,
        children=(
            Loop(
                "check", trip=128, ops=3, depth=4, pipelineable=False, parallel=False,
                children=(
                    Loop(
                        "edge", trip=6, ops=5, depth=5, ii_min=1,
                        branch=Branch(taken_ops=3, not_taken_ops=2, p_taken=0.5, nested=1),
                        pipelineable=True, parallel=False,
                    ),
                ),
            ),
        ),
    ),
)

gemm = Workload(
    "gemm",
    # 64x64x64 blocked matmul: classic imperfect nest (C-tile init/store in
    # the outer bodies), branch-free, fully parallel inner pipeline.
    Loop(
        "i", trip=64, ops=1, depth=3, pipelineable=False, parallel=True,
        children=(
            Loop(
                "j", trip=64, ops=2, depth=3, pipelineable=False, parallel=True,
                children=(
                    Loop(
                        "k", trip=64, ops=2, depth=4, ii_min=1,
                        pipelineable=True, parallel=True,
                    ),
                ),
            ),
        ),
    ),
)

# ---------------------------------------------------------------------------
# non-intensive (single-loop) benchmarks — the fairness controls of Fig. 17
# ---------------------------------------------------------------------------

conv1d = Workload(
    "conv-1d",
    Loop("i", trip=16_384, ops=6, depth=5, ii_min=1, pipelineable=True, parallel=True),
    intensive=False,
)

sigmoid = Workload(
    "sigmoid",
    Loop("i", trip=2048, ops=8, depth=7, ii_min=1, pipelineable=True, parallel=True),
    intensive=False,
)

gray = Workload(
    "gray-processing",
    Loop("i", trip=16_384, ops=4, depth=4, ii_min=1, pipelineable=True, parallel=True),
    intensive=False,
)


BENCHMARKS: Dict[str, Workload] = {
    w.name: w
    for w in [
        merge_sort, fft, viterbi, nw, hough, crc, adpcm, sc_decode, ldpc, gemm,
        conv1d, sigmoid, gray,
    ]
}

INTENSIVE = [n for n, w in BENCHMARKS.items() if w.intensive]
NON_INTENSIVE = [n for n, w in BENCHMARKS.items() if not w.intensive]

# Multi-layer nested loop benchmarks whose innermost loop pipelines (Fig. 15's
# selection criterion).
NESTED_PIPELINED = ["fft", "viterbi", "nw", "hough-transform", "sc-decode", "ldpc", "gemm"]


def workload(name: str) -> Workload:
    if name not in BENCHMARKS:
        raise KeyError(f"unknown benchmark {name!r}; known: {sorted(BENCHMARKS)}")
    return BENCHMARKS[name]
