"""Faithful cycle-level performance simulator of the paper's evaluation (§5-7).

Reproduces the paper's methodology: a parameterized performance model of
spatial-architecture execution, driven by per-benchmark CDFG loop trees with
the exact Table-5 data sizes, comparing PE execution models (von Neumann /
dataflow / Marionette with Proactive PE Configuration), control transports
(CCU / data-NoC / CS-Benes control network), and Agile PE Assignment, plus
performance models of Softbrain, TIA, REVEL and RipTide normalized to the
same 16-PE fabric (§6.1).
"""
from repro.sim.workload import Loop, Branch, Workload  # noqa: F401
from repro.sim.archs import ArchModel, ARCHS, marionette, von_neumann_pe, dataflow_pe  # noqa: F401
from repro.sim.engine import simulate, SimResult  # noqa: F401
from repro.sim.kernels import BENCHMARKS, workload  # noqa: F401
